import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST run before any jax import: jax locks the device count on first init.
#
# Multi-pod dry-run: lower + compile every (arch x shape) cell on the
# production mesh and extract memory / cost / collective analysis.
#
# This is the proof that the distribution config is coherent without real
# hardware: a sharding mismatch, an OOM-at-compile, or an unsupported
# collective fails the compile.  MUST be the process entry point.
#
# Usage:
#     python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
#     python -m repro.launch.dryrun --arch all --multi-pod --out out/dryrun
# (no `from __future__ import annotations` here -- the XLA_FLAGS line must
# stay the first statement, and __future__ imports can't follow it)

import argparse
import json
import sys
import time
import traceback

import numpy as np
import jax
import jax.numpy as jnp
from repro import compat
from jax.sharding import NamedSharding, PartitionSpec

from repro import configs
from repro.models import model as M
from repro.models.config import compute_dims
from repro.models.layers import split_tree
from repro.optim.adamw import make_adamw
from repro.optim.q8sharded import make_q8adam_sharded, state_pspecs as q8_specs
from repro.optim.schedules import warmup_cosine
from repro.sketchstream.monitor import SketchMonitorConfig, init_monitor
from . import roofline as RL
from . import shardings as SH
from . import serve as SV
from .mesh import make_production_mesh, batch_axes, data_shards
from .train import make_train_step, TrainState, state_shardings

# optimizer HBM decides AdamW vs Q8Adam: fp32 Adam needs 16 B/param.
Q8_THRESHOLD_BYTES = 10e9     # per chip


def _sds(shape, dtype, sharding):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _with_shardings(abstract_tree, sharding_tree):
    return jax.tree_util.tree_map(
        lambda a, s: _sds(a.shape, a.dtype, s), abstract_tree, sharding_tree)


def _src_len(seq: int) -> int:
    return max(seq // 4, 16)


def _enc_feats_spec(cfg, batch, seq, mesh):
    if not cfg.is_encdec:
        return None
    return _sds((batch, _src_len(seq), cfg.d_model), jnp.bfloat16,
                NamedSharding(mesh, PartitionSpec(batch_axes(mesh), None, None)))


def pick_optimizer(cfg, mesh, param_pspecs):
    n = cfg.param_count()
    chips = int(np.prod(list(mesh.shape.values())))
    lr = warmup_cosine(3e-4, 2000, 100_000)
    if n * 16 / chips > Q8_THRESHOLD_BYTES:
        return make_q8adam_sharded(mesh, lr, param_pspecs), "q8adam"
    return make_adamw(lr), "adamw"


def lower_train_cell(cfg, mesh, shape: configs.ShapeSpec, *,
                     monitor="deferred", remat: str = "full",
                     attn_chunk: int = 2048, ssm_chunk: int = 128,
                     seq_parallel: bool = False, probs_bf16: bool = False):
    dims = compute_dims(cfg, tp=mesh.shape["model"])
    bd = batch_axes(mesh)
    abstract_params = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg, dims))
    params_ab, axes = split_tree(abstract_params)
    pshard = SH.param_shardings(mesh, axes)
    ppspecs = SH.param_pspecs(mesh, axes)
    params_in = _with_shardings(params_ab, pshard)

    optimizer, opt_name = pick_optimizer(cfg, mesh, ppspecs)
    opt_ab = jax.eval_shape(optimizer.init, params_ab)
    if opt_name == "q8adam":
        opt_specs = SH.to_shardings(mesh, q8_specs(mesh, ppspecs))
        opt_in = _with_shardings(opt_ab, opt_specs)
    else:
        opt_in = _with_shardings(
            opt_ab, type(opt_ab)(
                step=NamedSharding(mesh, PartitionSpec()),
                m=pshard, v=pshard))

    mcfg = mparams = None
    monitor_in = None
    if monitor:
        # monitor="step" = paper-faithful per-step merge (replicated counters,
        # GSPMD all-reduces each step); "deferred" = shard-local counters
        # merged only at query time (the beyond-paper optimization).
        deferred = monitor != "step"
        mcfg = SketchMonitorConfig(shards=data_shards(mesh) if deferred else 1)
        mparams, mon_ab = init_monitor(mcfg)   # tiny concrete arrays are fine
        rep = NamedSharding(mesh, PartitionSpec())
        cspec = (NamedSharding(mesh, PartitionSpec(bd, None, None, None))
                 if deferred else rep)
        nspec = NamedSharding(mesh, PartitionSpec(bd)) if deferred else rep
        monitor_in = type(mon_ab)(
            counters=_sds(mon_ab.counters.shape, mon_ab.counters.dtype, cspec),
            n=_sds(mon_ab.n.shape, mon_ab.n.dtype, nspec),
            step=_sds((), jnp.int32, rep))

    rep = NamedSharding(mesh, PartitionSpec())
    state_in = TrainState(
        params=params_in, opt=opt_in, monitor=monitor_in,
        step=_sds((), jnp.int32, rep))

    bspec = NamedSharding(mesh, PartitionSpec(bd, None))
    batch_in = {
        "tokens": _sds((shape.batch, shape.seq), jnp.int32, bspec),
        "labels": _sds((shape.batch, shape.seq), jnp.int32, bspec),
    }
    ef = _enc_feats_spec(cfg, shape.batch, shape.seq, mesh)
    if ef is not None:
        batch_in["enc_feats"] = ef

    step_fn = make_train_step(cfg, dims, optimizer, mesh,
                              monitor_cfg=mcfg, monitor_params=mparams,
                              remat=remat, attn_chunk=attn_chunk,
                              ssm_chunk=ssm_chunk, seq_parallel=seq_parallel,
                              probs_dtype=(jnp.bfloat16 if probs_bf16
                                           else jnp.float32))
    with compat.set_mesh(mesh):
        lowered = jax.jit(step_fn).lower(state_in, batch_in)
    return lowered, {"optimizer": opt_name, "params": cfg.param_count(),
                     "active_params": cfg.active_param_count()}


def lower_prefill_cell(cfg, mesh, shape: configs.ShapeSpec, *,
                       attn_chunk: int = 2048, ssm_chunk: int = 128):
    dims = compute_dims(cfg, tp=mesh.shape["model"])
    bd = batch_axes(mesh)
    abstract_params = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg, dims))
    params_ab, axes = split_tree(abstract_params)
    params_ab = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, jnp.bfloat16)
        if a.dtype == jnp.float32 else a, params_ab)
    params_in = _with_shardings(params_ab, SH.param_shardings(mesh, axes))
    tokens = _sds((shape.batch, shape.seq), jnp.int32,
                  NamedSharding(mesh, PartitionSpec(bd, None)))
    fn = SV.make_prefill(cfg, dims, mesh, attn_chunk=attn_chunk,
                         ssm_chunk=ssm_chunk)
    ef = _enc_feats_spec(cfg, shape.batch, shape.seq, mesh)
    with compat.set_mesh(mesh):
        if ef is not None:
            lowered = jax.jit(fn).lower(params_in, tokens, ef)
        else:
            lowered = jax.jit(fn).lower(params_in, tokens)
    return lowered, {"params": cfg.param_count()}


def lower_decode_cell(cfg, mesh, shape: configs.ShapeSpec, *,
                      cache_layout: str = "auto"):
    dims = compute_dims(cfg, tp=mesh.shape["model"])
    bd = batch_axes(mesh)
    abstract_params = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg, dims))
    params_ab, axes = split_tree(abstract_params)
    params_ab = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, jnp.bfloat16)
        if a.dtype == jnp.float32 else a, params_ab)
    params_in = _with_shardings(params_ab, SH.param_shardings(mesh, axes))
    src = _src_len(shape.seq) if cfg.is_encdec else 0
    cache_ab, cache_sh = SV.cache_shardings(mesh, cfg, dims, shape.batch,
                                            shape.seq, src_len=src,
                                            layout=cache_layout)
    seq_mode = (SV.seq_sharded_mode(mesh, shape.batch)
                if cache_layout == "auto" else cache_layout == "seq")
    cache_in = _with_shardings(cache_ab, cache_sh)
    b_ax = None if seq_mode else bd
    token = _sds((shape.batch, 1), jnp.int32,
                 NamedSharding(mesh, PartitionSpec(b_ax, None)))
    fn = SV.make_decode_step(cfg, dims, mesh)
    with compat.set_mesh(mesh):
        lowered = jax.jit(fn).lower(params_in, token, cache_in)
    return lowered, {"params": cfg.param_count()}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             monitor="deferred", remat: str = "full",
             attn_chunk: int = 2048, ssm_chunk: int = 128,
             cache_layout: str = "auto", seq_parallel: bool = False,
             probs_bf16: bool = False,
             compile_: bool = True) -> dict:
    cfg = configs.get(arch)
    shape = configs.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    if shape.kind == "train":
        lowered, meta = lower_train_cell(cfg, mesh, shape, monitor=monitor,
                                         remat=remat, attn_chunk=attn_chunk,
                                         ssm_chunk=ssm_chunk,
                                         seq_parallel=seq_parallel,
                                         probs_bf16=probs_bf16)
    elif shape.kind == "prefill":
        lowered, meta = lower_prefill_cell(cfg, mesh, shape,
                                           attn_chunk=attn_chunk,
                                           ssm_chunk=ssm_chunk)
    else:
        lowered, meta = lower_decode_cell(cfg, mesh, shape,
                                          cache_layout=cache_layout)
    t_lower = time.time() - t0

    report = {
        "arch": arch, "shape": shape_name,
        "mesh": dict(mesh.shape), "chips": chips,
        "kind": shape.kind, "lower_s": round(t_lower, 1),
        "monitor": monitor if shape.kind == "train" else None,
        "remat": remat if shape.kind == "train" else None, **meta,
    }
    if not compile_:
        return report

    t0 = time.time()
    compiled = lowered.compile()
    report["compile_s"] = round(time.time() - t0, 1)

    try:
        mem = compiled.memory_analysis()
        report["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:                                     # CPU backend quirks
        report["memory"] = {"error": str(e)}

    tokens = shape.batch * (shape.seq if shape.kind != "decode" else 1)
    mf = RL.model_flops(cfg, tokens, train=(shape.kind == "train")) / chips
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    parsed = RL.hlo_cost(compiled.as_text())
    rl = RL.Roofline.build(parsed["flops"], parsed["hbm_bytes"],
                           parsed["total_wire_bytes"], model_flops=mf,
                           xla_flops_raw=float(cost.get("flops", 0.0)),
                           legalization_bytes=parsed["legalization_bytes"])
    report["roofline"] = rl.as_dict()
    report["collectives"] = {**parsed["collectives"],
                             "total_wire_bytes": parsed["total_wire_bytes"]}
    report["loops"] = parsed["loops"]
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-monitor", action="store_true")
    ap.add_argument("--monitor-mode", default="deferred",
                    choices=["deferred", "step"])
    ap.add_argument("--remat", default="full")
    ap.add_argument("--attn-chunk", type=int, default=2048)
    ap.add_argument("--cache-layout", default="auto",
                    choices=["auto", "batch", "seq"])
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--probs-bf16", action="store_true")
    ap.add_argument("--ssm-chunk", type=int, default=128)
    ap.add_argument("--tag", default=None, help="suffix for output JSON names")
    ap.add_argument("--out", default=None, help="directory for JSON reports")
    args = ap.parse_args(argv)

    archs = configs.ARCH_NAMES if args.arch == "all" else [args.arch]
    ok, failed = 0, []
    for arch in archs:
        shapes = [args.shape] if args.shape != "all" else list(configs.SHAPES)
        shapes = [s for s in shapes if configs.applicable(configs.get(arch), s)]
        if not shapes:
            print(f"[SKIP] {arch}/{args.shape}: inapplicable "
                  "(full attention, no sub-quadratic path; DESIGN.md §5)")
            continue
        for shape in shapes:
            tag = f"{arch}/{shape}/{'2pod' if args.multi_pod else '1pod'}"
            try:
                rep = run_cell(arch, shape, multi_pod=args.multi_pod,
                               monitor=(False if args.no_monitor
                                        else args.monitor_mode),
                               remat=args.remat, attn_chunk=args.attn_chunk,
                               ssm_chunk=args.ssm_chunk,
                               cache_layout=args.cache_layout,
                               seq_parallel=args.seq_parallel,
                               probs_bf16=args.probs_bf16)
                ok += 1
                print(f"[OK] {tag} lower={rep['lower_s']}s "
                      f"compile={rep.get('compile_s')}s "
                      f"dominant={rep.get('roofline', {}).get('dominant')}")
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    fn = f"{arch}__{shape}__{'2pod' if args.multi_pod else '1pod'}.json"
                    if args.tag:
                        fn = fn.replace(".json", f"__{args.tag}.json")
                    with open(os.path.join(args.out, fn), "w") as f:
                        json.dump(rep, f, indent=1)
            except Exception:
                failed.append(tag)
                print(f"[FAIL] {tag}")
                traceback.print_exc()
    print(f"\n{ok} cells OK, {len(failed)} failed")
    if failed:
        for t in failed:
            print("  FAIL:", t)
        sys.exit(1)


if __name__ == "__main__":
    main()
