"""Three-term roofline from a compiled dry-run artifact (no hardware).

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = wire_bytes_per_device / ICI link bw

**Why not ``compiled.cost_analysis()`` alone**: XLA's HloCostAnalysis counts
a while-loop body ONCE, not times its trip count -- with scan-over-layers
(the only way 72-layer 398B models compile in finite time) that undercounts
every per-layer flop, byte and collective by the layer count.  We therefore
parse the post-SPMD HLO text ourselves:

  * computations are split out; ``while`` instructions are mapped to their
    body/condition computations; the trip count is read from the condition's
    ``s32[] constant(N)``; multipliers propagate through nested loops
    (layer scan x chunked-attention scan).
  * FLOPs: every ``dot`` contributes 2 * output_elems * contraction_size
    (matmuls dominate; elementwise flops are ignored -- documented).
  * HBM bytes: for each top-level instruction in an executed computation,
    operand bytes + result bytes.  Post-fusion, top-level fusion boundaries
    are exactly the tensors that hit HBM; fusion-internal computations are
    excluded.  Parameter/tuple/bitcast/constant bookkeeping is skipped.
  * Collective wire bytes: result bytes (x2 for all-reduce: ring
    reduce-scatter + all-gather), times the loop multiplier.

Shapes in the partitioned module are per-device, so all numbers are
per-chip.  Hardware constants (TPU v5e per assignment): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (one direction)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
_WIRE_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

# instruction opcodes that don't move HBM bytes themselves
_SKIP_TRAFFIC = {"parameter", "get-tuple-element", "tuple", "bitcast",
                 "constant", "after-all", "add-dependency", "custom-call"}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems_dims(shape_str: str):
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class _Instr:
    name: str
    result_type: str
    opcode: str
    args: list          # operand %names
    text: str
    is_root: bool = False


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\)|\S+))\s+([\w\-]+)\(([^)]*(?:\([^)]*\))?[^)]*)\)",
)
_COMP_NAME_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)")


def _parse_computations(hlo: str):
    """-> (comps: name -> [raw lines], entry_name).

    A computation header is any top-level (unindented) line ending in '{'
    that contains a '->' return annotation; nested parens in the parameter
    list are common, so we only anchor on the leading name.
    """
    comps = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            if (stripped.endswith("{") and "->" in stripped
                    and not line.startswith(" ")):
                m = _COMP_NAME_RE.match(stripped)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
                    if stripped.startswith("ENTRY"):
                        entry = cur
        else:
            if stripped == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps, entry


def _parse_instrs(lines):
    out = []
    for line in lines:
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rtype, opcode, args = m.groups()
        arg_names = re.findall(r"%([\w.\-]+)", args)
        out.append(_Instr(name=name, result_type=rtype, opcode=opcode,
                          args=arg_names, text=line,
                          is_root=line.lstrip().startswith("ROOT ")))
    return out


def _dot_flops(instr: _Instr, shapes: dict) -> float:
    """2 * output_elems * contraction_size for a dot instruction."""
    out_dims = _shape_elems_dims(instr.result_type)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.text)
    if not m or not instr.args:
        return 2.0 * out_elems          # degenerate; count as elementwise-ish
    lhs_shape = shapes.get(instr.args[0], "")
    lhs_dims = _shape_elems_dims(lhs_shape)
    contract = 1
    for idx in m.group(1).split(","):
        if idx and int(idx) < len(lhs_dims):
            contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


def hlo_cost(hlo: str) -> dict:
    """Trip-count-aware per-device cost of a partitioned HLO module.

    Returns {"flops", "hbm_bytes", "collectives": {op: {...}},
             "total_wire_bytes", "loops": {body: trip}}.
    """
    comps, entry = _parse_computations(hlo)
    instrs = {name: _parse_instrs(lines) for name, lines in comps.items()}

    # global name -> result type (operand shape lookup)
    shapes = {}
    for ilist in instrs.values():
        for i in ilist:
            shapes[i.name] = i.result_type

    # while loops: body/cond + trip count
    loops = {}        # body comp -> (parent comp, trip)
    for cname, ilist in instrs.items():
        for i in ilist:
            if i.opcode != "while":
                continue
            mc = re.search(r"condition=%?([\w.\-]+)", i.text)
            mb = re.search(r"body=%?([\w.\-]+)", i.text)
            if not (mc and mb):
                continue
            trip = 1
            for line in comps.get(mc.group(1), []):
                for c in re.findall(r"s32\[\]\s+constant\((\d+)\)", line):
                    trip = max(trip, int(c))
            loops[mb.group(1)] = (cname, trip)

    # execution multipliers: ENTRY=1; while body = parent mult * trip
    mult = {entry: 1.0}
    changed = True
    while changed:
        changed = False
        for body, (parent, trip) in loops.items():
            if parent in mult:
                m = mult[parent] * trip
                if mult.get(body) != m:
                    mult[body] = m
                    changed = True

    # --- sliced-operand analysis for fusions -------------------------------
    # Scan-over-layers carries STACKED (layers, ...) buffers; each iteration
    # only dynamic-slices one layer out.  Charging the full stacked operand
    # per iteration would overcount HBM by the layer count, so: if a fusion
    # parameter is used ONLY by dynamic-slice ops inside the called
    # computation, charge the slice bytes instead of the full operand.
    def _fusion_param_costs(called: str):
        """-> {param_index: sliced_bytes or None (= full operand)}."""
        out = {}
        pname_to_idx = {}
        for fi in instrs.get(called, []):
            if fi.opcode == "parameter":
                mi = re.search(r"parameter\((\d+)\)", fi.text)
                if mi:
                    pname_to_idx[fi.name] = int(mi.group(1))
        uses = {}         # param name -> [instrs using it]
        for fi in instrs.get(called, []):
            for a in fi.args:
                if a in pname_to_idx:
                    uses.setdefault(a, []).append(fi)
        for pname, idx in pname_to_idx.items():
            us = uses.get(pname, [])
            if us and all(u.opcode == "dynamic-slice" and u.args
                          and u.args[0] == pname for u in us):
                out[idx] = sum(_shape_bytes(u.result_type) for u in us)
            elif us and all(u.opcode == "dynamic-update-slice" and u.args
                            and u.args[0] == pname for u in us):
                # in-place slot write: charge the update region, not the buffer
                out[idx] = sum(_shape_bytes(shapes.get(u.args[1], ""))
                               for u in us if len(u.args) >= 2)
            elif us and all(u.opcode == "scatter" and u.args
                            and u.args[0] == pname for u in us):
                out[idx] = sum(_shape_bytes(shapes.get(u.args[2], ""))
                               for u in us if len(u.args) >= 3)
            else:
                out[idx] = None
        return out

    _PURE_CONVERT = {"convert", "bitcast", "copy", "reshape", "transpose",
                     "dynamic-slice"}

    def _is_pure_convert_fusion(called: str) -> bool:
        """True if the fusion only moves/retypes data (no arithmetic).

        The CPU backend legalizes bf16 scatter/dot by round-tripping whole
        buffers through f32; a TPU executes bf16 natively and never
        materializes those converts.  Their traffic is tallied separately
        (``legalization_bytes``) so the memory term can be reported raw
        AND TPU-adjusted (DESIGN.md §9).
        """
        ops = [fi.opcode for fi in instrs.get(called, [])
               if fi.opcode != "parameter"]
        return bool(ops) and all(o in _PURE_CONVERT for o in ops) \
            and "convert" in ops

    flops = 0.0
    hbm = 0.0
    legal = 0.0
    colls = {k: {"count": 0, "bytes": 0.0, "wire_bytes": 0.0}
             for k in COLLECTIVE_OPS}
    for cname, m in mult.items():
        for i in instrs.get(cname, []):
            if i.opcode == "dot":
                flops += m * _dot_flops(i, shapes)
            param_costs = {}
            root_dus_bytes = None
            pure_convert = i.opcode == "convert"
            if i.opcode == "fusion":
                # dots inside fusion computations: attribute to the fusion site
                mcall = re.search(r"calls=%?([\w.\-]+)", i.text)
                if mcall:
                    for fi in instrs.get(mcall.group(1), []):
                        if fi.opcode == "dot":
                            flops += m * _dot_flops(fi, shapes)
                        if (fi.is_root and fi.opcode == "dynamic-update-slice"
                                and len(fi.args) >= 2):
                            # in-place slot write at the fusion root
                            root_dus_bytes = _shape_bytes(
                                shapes.get(fi.args[1], ""))
                        if (fi.is_root and fi.opcode == "scatter"
                                and len(fi.args) >= 3):
                            # in-place scatter: charge the updates region
                            root_dus_bytes = _shape_bytes(
                                shapes.get(fi.args[2], ""))
                    param_costs = _fusion_param_costs(mcall.group(1))
                    pure_convert = _is_pure_convert_fusion(mcall.group(1))
            if i.opcode in _SKIP_TRAFFIC or i.opcode == "while":
                continue
            out_b = _shape_bytes(i.result_type)
            if i.opcode == "fusion" and root_dus_bytes is not None:
                out_b = root_dus_bytes
            if i.opcode == "dynamic-slice":
                in_b = out_b                       # reads only the slice
            elif i.opcode == "dynamic-update-slice" and len(i.args) >= 2:
                # in-place: reads the update, writes the slice region
                upd = _shape_bytes(shapes.get(i.args[1], ""))
                in_b, out_b = upd, upd
            elif i.opcode == "scatter" and len(i.args) >= 3:
                # in-place scatter (KV-cache slot write): updates + indices
                upd = (_shape_bytes(shapes.get(i.args[2], ""))
                       + _shape_bytes(shapes.get(i.args[1], "")))
                in_b, out_b = upd, upd
            else:
                in_b = 0
                for ai, a in enumerate(i.args):
                    full = _shape_bytes(shapes.get(a, ""))
                    sliced = param_costs.get(ai)
                    in_b += sliced if sliced is not None else full
            hbm += m * (out_b + in_b)
            if pure_convert:
                legal += m * (out_b + in_b)
            base = i.opcode.removesuffix("-start")
            if base in colls:
                colls[base]["count"] += int(m)
                colls[base]["bytes"] += m * out_b
                colls[base]["wire_bytes"] += m * out_b * _WIRE_FACTOR[base]
    total_wire = sum(v["wire_bytes"] for v in colls.values())
    return {"flops": flops, "hbm_bytes": hbm, "collectives": colls,
            "total_wire_bytes": total_wire, "legalization_bytes": legal,
            "loops": {b: t for b, (_, t) in loops.items()}}


def parse_collectives(hlo_text: str) -> dict:
    """Back-compat: collective summary (trip-count aware)."""
    cost = hlo_cost(hlo_text)
    out = dict(cost["collectives"])
    out["total_wire_bytes"] = cost["total_wire_bytes"]
    return out


@dataclasses.dataclass
class Roofline:
    flops: float              # per device (dot flops, loop-expanded)
    hbm_bytes: float          # per device (fusion-boundary traffic)
    wire_bytes: float         # per device
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float = 0.0  # 6*N_active*D (train) / 2*N_active*D (serve), per device
    useful_ratio: float = 0.0
    xla_flops_raw: float = 0.0   # cost_analysis() as reported (body-once; reference)
    legalization_bytes: float = 0.0   # CPU bf16<->f32 round-trips (absent on TPU)
    memory_s_tpu: float = 0.0         # memory term net of legalization traffic

    @classmethod
    def build(cls, flops, hbm_bytes, wire_bytes, model_flops=0.0,
              xla_flops_raw=0.0, legalization_bytes=0.0):
        c = flops / PEAK_FLOPS
        m = hbm_bytes / HBM_BW
        n = wire_bytes / ICI_BW
        m_tpu = max(hbm_bytes - legalization_bytes, 0.0) / HBM_BW
        dom = max((("compute", c), ("memory", m), ("collective", n)),
                  key=lambda kv: kv[1])[0]
        return cls(flops=flops, hbm_bytes=hbm_bytes, wire_bytes=wire_bytes,
                   compute_s=c, memory_s=m, collective_s=n, dominant=dom,
                   model_flops=model_flops,
                   useful_ratio=(model_flops / flops) if flops else 0.0,
                   xla_flops_raw=xla_flops_raw,
                   legalization_bytes=legalization_bytes,
                   memory_s_tpu=m_tpu)

    def as_dict(self):
        return dataclasses.asdict(self)


def analyze_compiled(compiled, *, model_flops_per_device: float = 0.0) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    parsed = hlo_cost(compiled.as_text())
    return Roofline.build(parsed["flops"], parsed["hbm_bytes"],
                          parsed["total_wire_bytes"],
                          model_flops_per_device,
                          xla_flops_raw=float(cost.get("flops", 0.0)),
                          legalization_bytes=parsed["legalization_bytes"])


def model_flops(cfg, n_tokens: int, *, train: bool) -> float:
    """6*N_active*D for training, 2*N_active*D for inference (global)."""
    n = cfg.active_param_count()
    return (6.0 if train else 2.0) * n * n_tokens
