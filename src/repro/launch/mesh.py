"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state; the dry-run entry point force-creates 512 host
devices BEFORE calling this.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is an
extra data-parallel (FSDP) dimension over the slower inter-pod links --
gradient reduction over it is the target of the int8-compression option.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes that carry the batch (everything except model)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def data_shards(mesh) -> int:
    out = 1
    for a in batch_axes(mesh):
        out *= mesh.shape[a]
    return out
