"""Chunked, manifest-driven checkpoints with atomic commit + elastic restore.

Layout (one directory per step):

    ckpt_dir/
      step_000120.tmp-<nonce>/     # staging (never read)
      step_000120/
        manifest.json              # tree structure, shapes, chunking, step
        <leaf-id>.c<k>.npy         # chunk k of leaf

Every leaf is split along axis 0 into ``chunks`` pieces -- the shard-per-host
pattern: on a real cluster each host writes its own chunk; here one process
writes all of them, but the FORMAT is host-count independent, which is what
makes restore elastic: a checkpoint written with 16 chunks restores onto 4
hosts (or 1) by re-concatenation, and vice versa.  Commit is atomic: chunks
+ manifest land in a tmp dir that is os.rename()d into place (rename is
atomic on POSIX), so a crash mid-write never corrupts the latest checkpoint.

Sketch (monitor) state, optimizer moments and the step counter ride in the
same tree as params -- one commit covers the whole training state.
"""
from __future__ import annotations

import dataclasses
import json
import os
import secrets
import shutil

import numpy as np
import jax


@dataclasses.dataclass
class Manifest:
    step: int
    treedef: str
    leaves: list            # [{id, shape, dtype, chunks}]
    extra: dict

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=1)

    @classmethod
    def from_json(cls, s: str) -> "Manifest":
        return cls(**json.loads(s))


def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def save_checkpoint(ckpt_dir: str, step: int, tree, *, chunks: int = 4,
                    extra: dict | None = None, keep: int = 3) -> str:
    """Write tree (pytree of arrays) as a chunked checkpoint; returns path."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    final = _step_dir(ckpt_dir, step)
    tmp = final + f".tmp-{secrets.token_hex(4)}"
    os.makedirs(tmp, exist_ok=True)

    manifest_leaves = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        nchunks = min(chunks, arr.shape[0]) if arr.ndim > 0 else 1
        bounds = np.array_split(np.arange(arr.shape[0] if arr.ndim else 1), nchunks)
        for k, idx in enumerate(bounds):
            part = arr[idx[0]:idx[-1] + 1] if arr.ndim else arr
            np.save(os.path.join(tmp, f"leaf{i:05d}.c{k}.npy"), part)
        manifest_leaves.append({"id": i, "shape": list(arr.shape),
                                "dtype": str(arr.dtype), "chunks": nchunks})

    man = Manifest(step=step, treedef=str(treedef),
                   leaves=manifest_leaves, extra=extra or {})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        f.write(man.to_json())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic commit

    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and ".tmp" not in d)
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
    # stale staging dirs from crashed writers
    for d in os.listdir(ckpt_dir):
        if ".tmp-" in d:
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and ".tmp" not in d
             and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, template, *, step: int | None = None,
                       shardings=None):
    """Rebuild the tree.  ``template`` fixes the pytree structure; the chunk
    count on disk is independent of the restore topology (elastic).  When
    ``shardings`` (a matching tree of NamedSharding) is given, leaves are
    device_put with their target sharding (resharding on restore)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = _step_dir(ckpt_dir, step)
    with open(os.path.join(d, "manifest.json")) as f:
        man = Manifest.from_json(f.read())

    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    assert len(leaves_t) == len(man.leaves), \
        f"template has {len(leaves_t)} leaves, checkpoint {len(man.leaves)}"
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(leaves_t))

    out = []
    for meta, tmpl, shd in zip(man.leaves, leaves_t, shard_leaves):
        parts = [np.load(os.path.join(d, f"leaf{meta['id']:05d}.c{k}.npy"))
                 for k in range(meta["chunks"])]
        arr = parts[0] if len(parts) == 1 and not meta["shape"] else np.concatenate(parts, axis=0)
        arr = arr.reshape(meta["shape"]).astype(meta["dtype"])
        expect = tuple(getattr(tmpl, "shape", arr.shape))
        assert tuple(arr.shape) == expect, (arr.shape, expect)
        out.append(jax.device_put(arr, shd) if shd is not None else arr)
    return treedef.unflatten(out), man
