from .chunked import save_checkpoint, restore_checkpoint, latest_step, Manifest
